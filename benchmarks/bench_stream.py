"""Streaming subsystem benchmarks: chunked-fit throughput + assignment QPS.

Two families of rows, both landing in ``BENCH_stream.json`` (see
``run.py``):

  stream_fit_chunk<r>     one full out-of-core fit of the planted matrix
                          with ``r``-row chunks; µs per fit, derived field
                          carries rows/s. Sweeping the chunk size exposes
                          the fixed per-chunk cost (atom phase dispatch +
                          fold) vs chunk-amortized work — the knee is
                          where a deployment should size its chunks.
  stream_assign_*         batched out-of-sample assignment against the
                          fitted model (jitted ``assign_rows``/``assign_
                          cols``); µs per batch, derived carries QPS
                          (vectors assigned per second).
  serve_topk_assign_k<k>  top-k multi-assignment serving (DESIGN.md §11,
                          jitted ``assign_rows_topk``) at k=1 vs k=4; µs
                          per batch (mean), derived carries QPS and p99
                          latency — the marginal cost of overlap-mode
                          serving over argmax serving.
  stream_fit_recovery_overhead
                          the price of crash consistency: the same fit
                          with FitState checkpoints every 4 chunks
                          (atomic fsync'd commits, DESIGN.md §12) vs
                          none; µs for the checkpointed fit, derived
                          carries rows/s for both and the overhead %.
                          The acceptance bar is <= 15% at save_every=4.

CPU numbers are architecture proxies (the Pallas scoring kernel executes
in interpret mode off-TPU); the per-PR trajectory is the signal, as with
the other sections.
"""

from __future__ import annotations

import time

CHUNK_SIZES = (128, 256, 512)


def run(report, *, quick: bool = False) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import streaming
    from repro.core.metrics import nmi
    from repro.data import planted_cocluster_matrix

    m, n, k = (1024, 512, 5) if quick else (4096, 1024, 8)
    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, m, n, k=k, d=k, signal=4.0, noise=0.6)
    cfg = streaming.StreamConfig(n_row_clusters=k, n_col_clusters=k, seed=0)

    model = None
    for chunk_rows in CHUNK_SIZES:
        if chunk_rows > m:
            continue
        # first fit warms the per-chunk-shape jit caches; the second is the
        # steady-state throughput number a long-running ingester would see
        streaming.fit(streaming.iter_row_chunks(data.matrix, chunk_rows), cfg)
        t0 = time.perf_counter()
        model, stats = streaming.fit(
            streaming.iter_row_chunks(data.matrix, chunk_rows), cfg)
        dt = time.perf_counter() - t0
        quality = nmi(np.asarray(model.row_labels), data.row_labels)
        report(f"stream_fit_chunk{chunk_rows},{dt * 1e6:.0f},"
               f"rows_per_s={stats.rows_per_s:.0f};row_nmi={quality:.3f}")

    # recovery overhead: checkpointing every 4 chunks vs none, same fit.
    # Both runs re-use the warmed 256-row-chunk jit caches from above.
    import tempfile

    chunk_rows = 256
    streaming.fit(streaming.iter_row_chunks(data.matrix, chunk_rows), cfg)
    # best-of-3 on both sides: a single fit at this scale is tens of ms,
    # small enough that one scheduler hiccup would swamp the ~ms-scale
    # checkpoint cost being measured
    dt_off = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        _, stats_off = streaming.fit(
            streaming.iter_row_chunks(data.matrix, chunk_rows), cfg)
        dt_off = min(dt_off, time.perf_counter() - t0)
    dt_on = float("inf")
    for _ in range(3):
        with tempfile.TemporaryDirectory() as ckpt_dir:
            t0 = time.perf_counter()
            _, stats_on = streaming.fit(
                streaming.iter_row_chunks(data.matrix, chunk_rows), cfg,
                ckpt_dir=ckpt_dir, save_every=4)
            dt_on = min(dt_on, time.perf_counter() - t0)
    overhead = (dt_on - dt_off) / dt_off * 100.0
    del stats_off, stats_on  # rows/s below comes from the best-of-3 walls
    report(f"stream_fit_recovery_overhead,{dt_on * 1e6:.0f},"
           f"rows_per_s_on={m / dt_on:.0f};"
           f"rows_per_s_off={m / dt_off:.0f};"
           f"overhead_pct={overhead:.1f}")

    # assignment QPS against the last fitted model
    batch = 256
    reqs = jnp.asarray(rng.normal(size=(batch, n)).astype(np.float32))
    creqs = jnp.asarray(rng.normal(size=(batch, m)).astype(np.float32))
    row_step = jax.jit(lambda x: streaming.assign_rows(model, x))
    col_step = jax.jit(lambda y: streaming.assign_cols(model, y))
    for name, fn, x in (("stream_assign_rows", row_step, reqs),
                        ("stream_assign_cols", col_step, creqs)):
        jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(fn(x))
        us = (time.perf_counter() - t0) / reps * 1e6
        report(f"{name},{us:.0f},qps={batch / (us / 1e6):.0f}")

    # top-k multi-assignment serving: k=1 (argmax-equivalent) vs k=4 —
    # per-rep latencies so the derived field can carry a p99 next to QPS
    for k_top in (1, 4):
        fn = jax.jit(lambda x, k_=k_top: streaming.assign_rows_topk(
            model, x, k=k_))
        jax.block_until_ready(fn(reqs))
        # enough reps that the p99 is a real order statistic, not the
        # sample max set by one scheduler hiccup
        lat_us = []
        for _ in range(100):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(reqs))
            lat_us.append((time.perf_counter() - t0) * 1e6)
        lat = np.asarray(lat_us)
        mean_us = float(lat.mean())
        report(f"serve_topk_assign_k{k_top},{mean_us:.0f},"
               f"qps={batch / (mean_us / 1e6):.0f};"
               f"p99_us={float(np.percentile(lat, 99)):.0f}")
