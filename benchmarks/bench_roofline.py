"""Roofline table: merge the dry-run JSONL (compiled artifacts: memory fit,
HLO collective census, raw cost_analysis) with the analytic trip-count-
exact cost model (launch/analytic.py) into the §Roofline table.

Reports, per (arch x shape) on the single-pod mesh:
    compute_s / memory_s / collective_s  (analytic, v5e constants)
    dominant term, MODEL_FLOPS, useful ratio, HBM fit (from the compile).
"""

from __future__ import annotations

import json
import os

from repro.configs.base import cells
from repro.launch.analytic import analytic_cell
from repro.launch.roofline import HW, model_flops
from repro.launch.steps import padded_cfg

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.jsonl")


def load_dryrun(path=RESULTS):
    recs = {}
    if os.path.exists(path):
        for line in open(path):
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r.get("mesh", "singlepod"))] = r
    return recs


def cell_report(cfg, shape, chips=256, model_axis=16, fsdp_axis=16,
                pod_axis=1, measured=None):
    cfgp = padded_cfg(cfg)
    ac = analytic_cell(cfgp, shape, chips, model_axis, fsdp_axis, pod_axis)
    compute_s = ac.flops_global / (chips * HW["flops_bf16"])
    memory_s = ac.hbm_bytes_per_dev / HW["hbm_bw"]
    coll_s = ac.coll_bytes_per_dev / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfgp, shape)
    bound = max(terms.values())
    row = dict(
        arch=cfg.name, shape=shape.name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=mf,
        useful_ratio=mf / ac.flops_global if ac.flops_global else 0.0,
        roofline_frac=compute_s / bound if bound else 0.0,
        step_lower_bound_s=bound,
    )
    if measured:
        mem = measured.get("memory") or {}
        row["hbm_fit_gb"] = round(
            ((mem.get("temp_size_in_bytes") or 0)
             + (mem.get("argument_size_in_bytes") or 0)) / 2**30, 2)
        row["hlo_raw_flops"] = measured.get("hlo_flops")
        row["hlo_coll_bytes"] = measured.get("collective_bytes")
    return row


def run(report=print):
    recs = load_dryrun()
    rows = []
    for cfg, shape, live, why in cells(include_skipped=True):
        if not live:
            report(f"roofline_{cfg.name}_{shape.name},0,skipped:{why[:40]}")
            continue
        measured = recs.get((cfg.name, shape.name, "singlepod"))
        row = cell_report(cfg, shape, measured=measured)
        rows.append(row)
        report(
            f"roofline_{cfg.name}_{shape.name},"
            f"{row['step_lower_bound_s']*1e6:.0f},"
            f"dom={row['dominant']} comp={row['compute_s']:.4f}s "
            f"mem={row['memory_s']:.4f}s coll={row['collective_s']:.4f}s "
            f"frac={row['roofline_frac']:.2f} useful={row['useful_ratio']:.2f} "
            f"fitGB={row.get('hbm_fit_gb')}"
        )
    return rows


if __name__ == "__main__":
    run()
