"""Roofline rows for the repo's own hot kernels (-> BENCH_roofline.json).

Per kernel (``spmm_tiled``, ``spmm_ata``, ``kmeans_update``) at its bench
shape: measured wall time next to the *analytic* FLOPs and minimum HBM
bytes of the launch, reduced to achieved FLOP/s and bytes/s against the
TPU v5e peaks (``launch/roofline.py`` HW constants). This states every
kernel win against the hardware ceiling instead of the previous run: the
``us`` column tracks regressions, the ``pk`` fractions say how much
headroom is even left to chase, and the ``ai`` (arithmetic intensity,
FLOPs/byte) column says which wall — 240 FLOP/B is the v5e ridge — the
kernel lives under.

Off-TPU the kernels dispatch to their jnp tile-reference tier, so the
achieved numbers are the CPU production path's; the analytic FLOPs/bytes
columns are backend-independent. Row contract (benchmarks/run.py):
``roofline_<kernel>,us_per_call,derived``.
"""

from __future__ import annotations

import time

#: reps per timed row (after one warmup call).
_REPS = 3


def _time(fn, *args) -> float:
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(_REPS):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / _REPS


def _row(report, name: str, secs: float, flops: float, bytes_: float,
         extra: str) -> None:
    from repro.launch.roofline import HW

    ach_flops = flops / secs
    ach_bw = bytes_ / secs
    ai = flops / bytes_ if bytes_ else 0.0
    report(
        f"roofline_{name},{secs * 1e6:.0f},"
        f"flops={flops:.3g} bytes={bytes_:.3g} ai={ai:.1f} "
        f"ach_gflops={ach_flops / 1e9:.1f} ach_gbps={ach_bw / 1e9:.1f} "
        f"pk_flops={ach_flops / HW['flops_bf16']:.2e} "
        f"pk_hbm={ach_bw / HW['hbm_bw']:.2e} {extra}")


def _bcoo(rng, m: int, n: int, d: float):
    import jax.numpy as jnp
    from jax.experimental import sparse as jsparse

    dense = (rng.random((m, n)) < d) * rng.standard_normal((m, n))
    return jsparse.BCOO.fromdense(jnp.asarray(dense, jnp.float32))


def run(report=print, quick: bool = False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sparse as core_sparse
    from repro.kernels import ops as kops

    backend = jax.default_backend()
    rng = np.random.default_rng(0)
    m, n = (2048, 1024) if quick else (4096, 2048)
    r = 9
    d = 0.2

    a = _bcoo(rng, m, n, d)
    tiled = core_sparse.to_tiled(a)
    g, bm, bk = tiled.blocks.shape
    n_tr, n_tc = tiled.n_tiles
    # RHS width as launched: the Pallas tiers pad the skinny sketch to one
    # bn=128 column stripe; the jnp tile reference contracts the real r
    wn = r if kops._tiled_backend() == "jnp" else 128

    # -- spmm_tiled: one (bm, bk) @ (bk, wn) MXU contraction per payload.
    # HBM floor: payload stack + one rhs stripe + one output stripe.
    x = jnp.asarray(rng.standard_normal((n, r)).astype(np.float32))
    f = jax.jit(lambda b: kops.spmm_tiled(tiled, b))
    secs = _time(f, x)
    flops = 2.0 * g * bm * bk * wn
    bytes_ = 4.0 * (g * bm * bk + n_tc * bk * wn + n_tr * bm * wn)
    _row(report, "spmm_tiled", secs, flops, bytes_,
         f"backend={backend} g={g} grid={n_tr}x{n_tc}")

    # -- spmm_ata: both products of A.T @ (A @ x) in one launch; the Y
    # intermediate stays in VMEM, so HBM traffic is the payloads (read
    # once per phase) + x + the output stripe — Y never counts.
    f = jax.jit(lambda b: kops.spmm_ata(tiled, b))
    secs = _time(f, x)
    flops = 4.0 * g * bm * bk * wn
    bytes_ = 4.0 * (2 * g * bm * bk + 2 * n_tc * bk * wn)
    _row(report, "spmm_ata", secs, flops, bytes_,
         f"backend={backend} g={g} fused_y_vmem={n_tr * bm * wn * 4}")

    # -- kmeans_update: fused one-pass Lloyd iteration (DESIGN.md §4).
    # FLOPs: the (P, K) distance matrix via the 2xy matmul term; HBM
    # floor: x read once (the point of the fusion) + centroids + outputs.
    p, dim, k = (2048, 64, 16) if quick else (4096, 64, 16)
    xs = jnp.asarray(rng.standard_normal((p, dim)).astype(np.float32))
    cs = jnp.asarray(rng.standard_normal((k, dim)).astype(np.float32))
    f = jax.jit(lambda xx, cc: kops.kmeans_update(xx, cc))
    secs = _time(f, xs, cs)
    flops = 2.0 * p * k * dim + 2.0 * p * dim  # distances + sums scatter
    bytes_ = 4.0 * (p * dim + k * dim + p * 2 + k * dim + k)
    _row(report, "kmeans_update", secs, flops, bytes_,
         f"backend={backend} p={p} d={dim} k={k}")


if __name__ == "__main__":
    run()
