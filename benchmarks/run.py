"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract) and, at
exit, writes machine-readable ``{name: µs/call}`` trajectory files so
per-PR perf trajectories can be diffed without parsing stdout. Each file
owns one key namespace — ``sparse_*`` rows go (only) to
``BENCH_sparse.json``, ``stream_*``/``serve_*`` rows to
``BENCH_stream.json``, ``roofline_*`` rows to ``BENCH_roofline.json``,
and every other row to ``BENCH_atoms.json`` — and stale foreign keys are
scrubbed on rewrite. Sections (described in benchmarks/README.md):
  table2_*      running-time reproduction (paper Table II)
  table3_*      NMI/ARI reproduction (paper Table III)
  prob_bound_*  Theorem-1 bound tightness (paper Eq. 3)
  roofline_*    achieved-vs-peak FLOPs/bytes for the repo's hot kernels
                (-> ``BENCH_roofline.json``)
  kernel_*      Pallas kernel micro-benches (interpret-mode correctness +
                jnp-path wall time; TPU wall time requires hardware)
  sparse_*      sparse atom phase: routed SpMM backends vs the
                densify-then-run baseline (-> ``BENCH_sparse.json``)
  stream_*      out-of-core chunked-fit throughput + assignment QPS
                (-> ``BENCH_stream.json``)
  serve_load_*  assignment-service load tests: traffic mixes through the
                admission queue + coalescer, swap-under-load
                (-> ``BENCH_stream.json``)

``--list`` prints the available section names and exits.
"""

from __future__ import annotations

import argparse
import time


def _kernel_micro(report):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import kmeans as km
    from repro.models.attention import chunked_causal_attention

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    key = jax.random.key(0)
    f = jax.jit(lambda: km.kmeans(key, x, 16, n_iter=10).labels)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f().block_until_ready()
    report(f"kernel_kmeans_4096x64_k16,{(time.perf_counter()-t0)/3*1e6:.0f},jnp_path")

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    g = jax.jit(lambda: chunked_causal_attention(q, q, q, chunk_size=256))
    g().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        g().block_until_ready()
    report(f"kernel_chunked_attn_1k,{(time.perf_counter()-t0)/3*1e6:.0f},jnp_path")

    _kernel_kmeans_fused(report)


def _kernel_kmeans_fused(report):
    """One Lloyd iteration: jnp 3-pass update vs fused one-pass kernel.

    On TPU the fused path reads ``x`` from HBM once instead of three times
    and never materializes the ``(P, K)`` one-hot (DESIGN.md §4). On CPU
    the kernel runs in Pallas interpret mode, so its wall time here is a
    correctness proxy only — the jnp row is the meaningful CPU number.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    rng = np.random.default_rng(1)
    p, d, k = 4096, 64, 16
    x = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))

    f_jnp = jax.jit(lambda: kref.kmeans_update_ref(x, c))
    f_fused = jax.jit(lambda: kops.kmeans_update(x, c))
    for name, fn in (("kernel_kmeans_update_jnp", f_jnp),
                     ("kernel_kmeans_update_fused", f_fused)):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        backend = "jnp_3pass" if name.endswith("jnp") else (
            "fused_1pass" if jax.default_backend() == "tpu"
            else "fused_1pass_interpret")
        report(f"{name},{(time.perf_counter()-t0)/3*1e6:.0f},{backend}")


SECTIONS = ("prob", "roofline", "kernel", "sparse", "stream", "serve",
            "table3", "table2")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller table2/3 problem sizes")
    ap.add_argument("--only", default=None,
                    help="run a single section: " + "|".join(SECTIONS))
    ap.add_argument("--list", action="store_true",
                    help="print available section names and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in SECTIONS:
            print(name)
        return

    rows: dict[str, float] = {}

    def report(line: str) -> None:
        print(line, flush=True)
        # rows follow the "name,us_per_call,derived" contract; keep every
        # one whose second field parses as a number
        parts = line.split(",")
        if len(parts) >= 2:
            try:
                rows[parts[0]] = float(parts[1])
            except ValueError:
                pass

    sections = args.only.split(",") if args.only else list(SECTIONS)
    unknown = [s for s in sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; available: {', '.join(SECTIONS)}")

    if "prob" in sections:
        from benchmarks import bench_probability
        bench_probability.run(report)
    if "roofline" in sections:
        from benchmarks import bench_roofline
        bench_roofline.run(report, quick=args.quick)
    if "kernel" in sections:
        _kernel_micro(report)
    if "sparse" in sections:
        from benchmarks import bench_sparse
        bench_sparse.run(report, quick=args.quick)
    if "stream" in sections:
        from benchmarks import bench_stream
        bench_stream.run(report, quick=args.quick)
    if "serve" in sections:
        from benchmarks import bench_serve
        bench_serve.run(report, quick=args.quick)
    if "table3" in sections:
        from benchmarks import bench_table3
        bench_table3.run(report, rcv1_scale=0.05 if args.quick else 0.2)
    if "table2" in sections:
        from benchmarks import bench_table2
        bench_table2.run(report)

    # merge into any existing file so `--only` runs refresh their section
    # without clobbering the rest of the trajectory record. Each file owns
    # one key namespace: sparse_* -> BENCH_sparse.json, stream_*/serve_*
    # -> BENCH_stream.json, everything else -> BENCH_atoms.json. Each
    # section writes only its own keys to its own file, and stale foreign
    # keys (left by older, differently-routed writers) are scrubbed on
    # rewrite.
    from repro.benchio import merge_rows

    def _merge_write(path: str, new_rows: dict, **scrub) -> None:
        total = merge_rows(path, new_rows, **scrub)
        print(f"wrote {path} ({len(new_rows)} new / {total} total entries)",
              flush=True)

    sparse_rows = {k: v for k, v in rows.items() if k.startswith("sparse_")}
    stream_rows = {k: v for k, v in rows.items()
                   if k.startswith(("stream_", "serve_"))}
    roofline_rows = {k: v for k, v in rows.items()
                     if k.startswith("roofline_")}
    atom_rows = {k: v for k, v in rows.items()
                 if k not in sparse_rows and k not in stream_rows
                 and k not in roofline_rows}
    if atom_rows:
        _merge_write("BENCH_atoms.json", atom_rows,
                     foreign_prefixes=("sparse_", "stream_", "serve_",
                                       "roofline_"))
    if sparse_rows:
        # replace_prefixes: the sparse section regenerates its whole row
        # family every run, so renamed/retired rows can't accrete
        _merge_write("BENCH_sparse.json", sparse_rows,
                     own_prefixes=("sparse_",),
                     replace_prefixes=("sparse_",))
    if stream_rows:
        # the serve-load family regenerates whole when its section ran:
        # replace it so renamed/retired mixes cannot accrete
        _merge_write("BENCH_stream.json", stream_rows,
                     own_prefixes=("stream_", "serve_"),
                     replace_prefixes=(("serve_load_",)
                                       if "serve" in sections else ()))
    if roofline_rows:
        _merge_write("BENCH_roofline.json", roofline_rows,
                     own_prefixes=("roofline_",),
                     replace_prefixes=("roofline_",))


if __name__ == "__main__":
    main()
