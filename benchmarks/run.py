"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract). Sections:
  table2_*      running-time reproduction (paper Table II)
  table3_*      NMI/ARI reproduction (paper Table III)
  prob_bound_*  Theorem-1 bound tightness (paper Eq. 3)
  roofline_*    per-cell roofline terms (EXPERIMENTS.md §Roofline)
  kernel_*      Pallas kernel micro-benches (interpret-mode correctness +
                jnp-path wall time; TPU wall time requires hardware)
"""

from __future__ import annotations

import argparse
import time


def _kernel_micro(report):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import kmeans as km
    from repro.models.attention import chunked_causal_attention

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    key = jax.random.key(0)
    f = jax.jit(lambda: km.kmeans(key, x, 16, n_iter=10).labels)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f().block_until_ready()
    report(f"kernel_kmeans_4096x64_k16,{(time.perf_counter()-t0)/3*1e6:.0f},jnp_path")

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    g = jax.jit(lambda: chunked_causal_attention(q, q, q, chunk_size=256))
    g().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        g().block_until_ready()
    report(f"kernel_chunked_attn_1k,{(time.perf_counter()-t0)/3*1e6:.0f},jnp_path")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller table2/3 problem sizes")
    ap.add_argument("--only", default=None,
                    help="run a single section: table2|table3|prob|roofline|kernel")
    args = ap.parse_args(argv)

    def report(line: str) -> None:
        print(line, flush=True)

    sections = (args.only.split(",") if args.only
                else ["prob", "roofline", "kernel", "table3", "table2"])

    if "prob" in sections:
        from benchmarks import bench_probability
        bench_probability.run(report)
    if "roofline" in sections:
        from benchmarks import bench_roofline
        bench_roofline.run(report)
    if "kernel" in sections:
        _kernel_micro(report)
    if "table3" in sections:
        from benchmarks import bench_table3
        bench_table3.run(report, rcv1_scale=0.05 if args.quick else 0.2)
    if "table2" in sections:
        from benchmarks import bench_table2
        bench_table2.run(report)


if __name__ == "__main__":
    main()
