"""Service load-test harness: the assignment service under real traffic.

Where ``bench_stream.py`` times the bare jitted assign step, this section
drives the full :class:`repro.streaming.AssignService` stack — admission,
fixed-shape batch coalescing, worker replicas, hot swap — under the
traffic mixes a deployment actually sees (DESIGN.md §15). Rows (all land
in ``BENCH_stream.json``; µs column is the p50 submit→fulfil latency,
which *includes* queueing, unlike the direct-loop numbers):

  serve_load_baseline   single-process direct jit loop at the same batch
                        size — the per-replica floor the service is
                        measured against; derived carries QPS.
  serve_load_uniform    uniform random quarter-batch requests, k=1, one
                        replica; derived carries QPS, per-replica QPS,
                        p99 and mean batch fill %.
  serve_load_hotkey     hot-key skew: 90% of requests share one payload
                        (a viral item being re-scored) — exercises the
                        coalescer's fairness, not a cache (scoring is
                        O(batch) regardless).
  serve_load_topk_k4    k=4 overlap-mode traffic (DESIGN.md §11) — the
                        marginal service cost of top-k over argmax.
  serve_load_cols       column-axis traffic (feature width = n_rows).
  serve_load_swap       sustained multi-thread traffic across a hot
                        model swap; derived carries QPS, errors (the
                        harness *fails* unless 0) and versions seen
                        (must be 2: responses from both sides of the
                        swap, each attributable to exactly one).

``--dry-run`` runs every mix at smoke scale with the same invariant
checks and writes nothing — the CI serve-smoke lane runs it; a dropped
or errored request under swap fails the process, not just a number.

CPU numbers are architecture proxies (interpret-mode kernels); the
per-PR trajectory is the signal, as with the other sections.
"""

from __future__ import annotations

import argparse
import threading
import time


def _fit_model(seed: int, *, quick: bool):
    import numpy as np

    from repro import streaming
    from repro.data import planted_cocluster_matrix

    m, n, k = (512, 256, 5) if quick else (2048, 512, 8)
    rng = np.random.default_rng(seed)
    data = planted_cocluster_matrix(rng, m, n, k=k, d=k, signal=4.0,
                                    noise=0.6)
    cfg = streaming.StreamConfig(n_row_clusters=k, n_col_clusters=k,
                                 seed=seed)
    model, _ = streaming.fit(
        streaming.iter_row_chunks(data.matrix, max(128, m // 4)), cfg)
    return model


def _drive(model, payloads, *, axis: str, k: int, replicas: int,
           batch: int) -> dict:
    """Submit ``payloads`` through a fresh service; return traffic stats.

    A fresh service (and metrics registry) per mix keeps every mix's
    percentiles isolated. The first submit is a warm-up for the (axis, k)
    scorer so the timed stream measures serving, not tracing.
    """
    from repro import obs, streaming

    reg = obs.Registry()
    cfg = streaming.ServeConfig(
        batch=batch, replicas=replicas,
        max_queue_rows=sum(p.shape[0] for p in payloads) + batch)
    with streaming.AssignService(model, version="v1", config=cfg,
                                 metrics=reg) as svc:
        svc.submit(payloads[0], axis=axis, k=k).result(timeout=120.0)
        t0 = time.perf_counter()
        tickets = [svc.submit(x, axis=axis, k=k) for x in payloads]
        results = [t.result(timeout=120.0) for t in tickets]
        wall = time.perf_counter() - t0
        stats = svc.stats()
    rows = sum(len(r.labels) for r in results if r.ok)
    errors = sum(not r.ok for r in results)
    return {
        "rows": rows, "errors": errors,
        "qps": rows / max(wall, 1e-9),
        "p50_us": stats["p50_request_us"],
        "p99_us": stats["p99_request_us"],
        "fill_pct": stats["mean_batch_fill_pct"],
    }


def _swap_under_load(model, model2, *, batch: int, n_requests: int) -> dict:
    """Pump traffic from 3 threads, hot-swap at the halfway mark.

    Returns stats incl. the set of versions observed in responses. Every
    response must be ok (the zero-drop guarantee) and attributable to
    exactly one version; the caller asserts both.
    """
    import numpy as np

    from repro import obs, streaming

    reg = obs.Registry()
    size = max(1, batch // 4)
    dim = model.n_cols
    cfg = streaming.ServeConfig(batch=batch, replicas=2,
                                max_queue_rows=8 * batch)
    results: list = []
    lock = threading.Lock()
    stop = threading.Event()

    with streaming.AssignService(model, version="v1", config=cfg,
                                 metrics=reg) as svc:
        warm = np.zeros((size, dim), np.float32)
        svc.submit(warm).result(timeout=120.0)

        def pump(seed: int) -> None:
            rng = np.random.default_rng(seed)
            while not stop.is_set():
                x = rng.normal(size=(size, dim)).astype(np.float32)
                res = svc.submit(x).result(timeout=120.0)
                with lock:
                    results.append(res)

        threads = [threading.Thread(target=pump, args=(i,), daemon=True)
                   for i in range(3)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        while len(results) < n_requests // 2:
            time.sleep(0.002)
        svc.swap(model2, "v2")
        # the pumps kept running through the (slow) pre-warm above, so
        # gate on responses *after* the publish, not a raw total — else
        # the stream can end before a single v2 response exists
        with lock:
            at_swap = len(results)
        while len(results) < max(n_requests, at_swap + 6):
            time.sleep(0.002)
        stop.set()
        for t in threads:
            t.join(timeout=120.0)
        wall = time.perf_counter() - t0
        stats = svc.stats()

    rows = sum(len(r.labels) for r in results if r.ok)
    errors = sum(not r.ok for r in results)
    return {
        "rows": rows, "errors": errors,
        "qps": rows / max(wall, 1e-9),
        "p50_us": stats["p50_request_us"],
        "p99_us": stats["p99_request_us"],
        "versions": sorted({r.version for r in results if r.ok}),
    }


def run(report, *, quick: bool = False, dry_run: bool = False) -> None:
    import jax
    import numpy as np

    from repro import streaming

    quick = quick or dry_run
    batch = 32 if quick else 64
    n_req = 24 if dry_run else (64 if quick else 192)
    size = max(1, batch // 4)

    model = _fit_model(0, quick=quick)
    model2 = _fit_model(1, quick=quick)
    dim_rows, dim_cols = model.n_cols, model.n_rows
    rng = np.random.default_rng(2)

    # baseline: the direct jit loop at the same batch size — what one
    # process gets with zero service machinery; per-replica service QPS
    # is judged against this floor
    step = jax.jit(lambda x: streaming.assign_rows(model, x))
    xb = rng.normal(size=(batch, dim_rows)).astype(np.float32)
    jax.block_until_ready(step(xb))
    reps = max(4, n_req // 4)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(step(xb))
    us = (time.perf_counter() - t0) / reps * 1e6
    base_qps = batch / (us / 1e6)
    report(f"serve_load_baseline,{us:.0f},qps={base_qps:.0f}")

    def uniform(n: int, dim: int) -> list:
        return [rng.normal(size=(size, dim)).astype(np.float32)
                for _ in range(n)]

    hot = rng.normal(size=(size, dim_rows)).astype(np.float32)
    hotkey = [hot if i % 10 else
              rng.normal(size=(size, dim_rows)).astype(np.float32)
              for i in range(n_req)]

    mixes = (
        ("serve_load_uniform", uniform(n_req, dim_rows),
         dict(axis="rows", k=1, replicas=1)),
        ("serve_load_hotkey", hotkey, dict(axis="rows", k=1, replicas=2)),
        ("serve_load_topk_k4", uniform(n_req, dim_rows),
         dict(axis="rows", k=4, replicas=1)),
        ("serve_load_cols", uniform(n_req, dim_cols),
         dict(axis="cols", k=1, replicas=1)),
    )
    for name, payloads, kw in mixes:
        d = _drive(model, payloads, batch=batch, **kw)
        if d["errors"]:
            raise RuntimeError(
                f"{name}: {d['errors']} well-formed requests rejected")
        per_rep = d["qps"] / kw["replicas"]
        report(f"{name},{d['p50_us']:.0f},qps={d['qps']:.0f};"
               f"per_replica_qps={per_rep:.0f};p99_us={d['p99_us']:.0f};"
               f"fill_pct={d['fill_pct']:.0f}")

    d = _swap_under_load(model, model2, batch=batch, n_requests=n_req)
    if d["errors"]:
        raise RuntimeError(
            f"serve_load_swap: {d['errors']} requests dropped/errored "
            "across the hot swap — the zero-drop guarantee is broken")
    if d["versions"] != ["v1", "v2"]:
        raise RuntimeError(
            f"serve_load_swap: expected responses from both model "
            f"versions, saw {d['versions']}")
    report(f"serve_load_swap,{d['p50_us']:.0f},qps={d['qps']:.0f};"
           f"p99_us={d['p99_us']:.0f};errors={d['errors']};"
           f"versions={len(d['versions'])};rows={d['rows']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller model + shorter streams")
    ap.add_argument("--dry-run", action="store_true",
                    help="smoke scale, full invariant checks, no file "
                         "writes (the CI serve-smoke lane)")
    ap.add_argument("--bench-out", default="BENCH_stream.json",
                    help="merge rows into this file ('' to skip)")
    args = ap.parse_args(argv)

    rows: dict[str, float] = {}

    def report(line: str) -> None:
        print(line, flush=True)
        parts = line.split(",")
        if len(parts) >= 2:
            try:
                rows[parts[0]] = float(parts[1])
            except ValueError:
                pass

    run(report, quick=args.quick, dry_run=args.dry_run)

    if args.dry_run:
        print("bench_serve --dry-run OK (all invariants held)")
        return
    if args.bench_out:
        from repro.benchio import merge_rows

        # serve_load_* regenerates whole per run: replace, don't accrete
        total = merge_rows(args.bench_out, rows,
                           own_prefixes=("stream_", "serve_"),
                           replace_prefixes=("serve_load_",))
        print(f"wrote {args.bench_out} ({len(rows)} new / {total} total "
              "entries)")


if __name__ == "__main__":
    main()
