"""Sparse atom phase: BCOO SpMM path vs densify-then-run baseline.

The paper's headline efficiency claim covers sparse inputs ("up to 30%
for sparse matrices"); this section measures the repo's sparse execution
path at RCV1-like densities. For each density we time the *atom phase*
(bipartite normalization + randomized subspace SVD — the per-block hot
loop) two ways on the same BCOO matrix:

  sparse_atom_bcoo_d*    sparse path: one dual-ELL conversion (timed, it
                         is part of the path) + normalize + SVD with
                         gather-only SpMM products (O(nnz * rank))
  sparse_atom_dense_d*   densify-then-run: ``todense()`` + the dense
                         pipeline (O(M * N * rank)) — what a caller
                         without the sparse path must do

plus raw single-product SpMM microbenches (COO segment-sum vs densify;
a single product can't amortize the ELL conversion, so the scatter
formulation is the honest one-shot number). Rows land in
``BENCH_sparse.json`` (see ``run.py``); the acceptance bar is bcoo <
dense at density <= 0.05. At 0.2 the dense path may win — gathered
products lose to a saturated MXU/BLAS matmul once nnz approaches the
block area; that crossover is exactly the asymmetry the density-aware
plan cost models (``probability._atom_cost``).
"""

from __future__ import annotations

import time

DENSITIES = (0.01, 0.05, 0.2)


def _time(fn, *args, reps: int = 3) -> float:
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run(report, *, quick: bool = False, densities=DENSITIES) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sparse as core_sparse
    from repro.core.spectral import normalize_bipartite, randomized_svd
    from repro.data import planted_cocluster_matrix, to_bcoo
    from repro.kernels import ops as kops

    m, n = (2048, 1024) if quick else (4096, 2048)
    rank, n_iter = 9, 4
    key = jax.random.key(0)

    @jax.jit
    def _atom_ell(ell):
        a_n, _, _ = normalize_bipartite(ell)
        return randomized_svd(key, a_n, rank=rank, n_iter=n_iter)

    def atom_sparse(a_sp):
        # the dual-ELL conversion is part of the sparse path and timed;
        # it is the one-off analogue of the baseline's todense()
        return _atom_ell(core_sparse.to_ell(a_sp))

    @jax.jit
    def atom_densify(a_sp):
        a_n, _, _ = normalize_bipartite(a_sp.todense())
        return randomized_svd(key, a_n, rank=rank, n_iter=n_iter)

    @jax.jit
    def spmm_bcoo(a_sp, omega):
        return kops.spmm(a_sp, omega)

    @jax.jit
    def spmm_densify(a_sp, omega):
        return a_sp.todense() @ omega

    rng = np.random.default_rng(0)
    omega = jnp.asarray(rng.normal(size=(n, rank)).astype(np.float32))
    for d in densities:
        data = planted_cocluster_matrix(rng, m, n, k=8, d=8,
                                        signal=5.0, noise=0.4, density=d)
        a_sp = to_bcoo(data.matrix)
        us_sp = _time(atom_sparse, a_sp)
        us_de = _time(atom_densify, a_sp)
        report(f"sparse_atom_bcoo_d{d},{us_sp:.0f},spmm_path_nnz={a_sp.nse}")
        report(f"sparse_atom_dense_d{d},{us_de:.0f},densify_then_run")
        report(f"sparse_spmm_bcoo_d{d},{_time(spmm_bcoo, a_sp, omega):.0f},"
               f"segment_sum")
        report(f"sparse_spmm_dense_d{d},{_time(spmm_densify, a_sp, omega):.0f},"
               f"densify_matmul")

    # tile-level kernel: correctness-proxy timing off-TPU (interpret mode),
    # real wall time on TPU — same caveat as kernel_kmeans_update_fused.
    data = planted_cocluster_matrix(rng, 512, 512, k=8, d=8,
                                    signal=5.0, noise=0.4, density=0.05)
    a_sp = to_bcoo(data.matrix)
    bs = kops.bcoo_to_block_sparse(a_sp, bm=128, bk=128)
    omega_s = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    backend = ("tiled_kernel" if jax.default_backend() == "tpu"
               else "tiled_kernel_interpret")
    occupancy = bs.blocks.shape[0] / ((512 // 128) ** 2)
    us = _time(lambda: kops.spmm_tiled(bs, omega_s))
    report(f"sparse_spmm_tiled_512_d0.05,{us:.0f},{backend}_occ={occupancy:.2f}")
