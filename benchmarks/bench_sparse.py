"""Sparse atom phase: routed SpMM backends vs densify-then-run baseline.

The paper's headline efficiency claim covers sparse inputs ("up to 30%
for sparse matrices"); this section measures the repo's sparse execution
path at RCV1-like densities. For each density we time the *atom phase*
(bipartite normalization + randomized subspace SVD — the per-block hot
loop) on the same BCOO matrix through every backend:

  sparse_atom_bcoo_d*    legacy sparse path: one dual-ELL conversion per
                         call (timed — the unamortized worst case) +
                         normalize + SVD with gather-only SpMM products
  sparse_atom_ell_d*     dual-ELL path with the conversion hoisted out —
                         the LAMC single-block route, where one host
                         conversion is amortized across every resample's
                         ~10 subspace-iteration products
  sparse_atom_tiled_d*   tiled block-sparse path (amortized conversion):
                         batched tile GEMM products + the fused
                         normal-equations pass (kernels.ops.spmm_ata)
  sparse_atom_auto_d*    whatever probability.spmm_route picks for the
                         density — the spmm_impl="auto" dispatch LAMC
                         actually runs
  sparse_atom_dense_d*   densify-then-run: ``todense()`` + the dense
                         pipeline (O(M * N * rank)) — what a caller
                         without the sparse path must do
  sparse_prep_{ell,tiled}_d*  the one-time conversions being amortized
                         (reported so the trade is auditable); the tiled
                         row is the *cold* path — no pattern cache
  sparse_prep_tiled_pattern_d*  pattern analysis alone (the part the
                         conversion cache reuses across same-pattern
                         resamples/re-chunks)
  sparse_prep_tiled_values_d*  values refresh through a warm plan — what
                         a same-pattern, new-data conversion costs
  sparse_atom_auto_warm_d*  the routed atom including a warm-cache
                         ``prepare_operator`` call, with the prep share
                         in the derived column (``prep_pct``)

plus raw single-product micro rows: COO segment-sum vs densify (a single
product can't amortize any conversion, so the scatter formulation is the
honest one-shot number), the tile-level kernel, and the fused
``Aᵀ(A·X)`` normal-equations pass vs its two-launch formulation. Rows
land in ``BENCH_sparse.json`` (see ``run.py``). The acceptance bars:
the routed (auto) atom beats the legacy bcoo row at density <= 0.05 and
never loses to densify-then-run at 0.2 — the d=0.2 regression of the
gather path is gone by construction, because past the dual-ELL
crossover (``probability.SPMM_ELL_CROSSOVER``) the route is a
tile/dense contraction, never a per-nonzero gather.
"""

from __future__ import annotations

import time

DENSITIES = (0.01, 0.05, 0.2)


def _time(fn, *args, reps: int = 3) -> float:
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run(report, *, quick: bool = False, densities=DENSITIES) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import opcache, probability
    from repro.core import sparse as core_sparse
    from repro.kernels import spmm as kspmm
    from repro.core.spectral import normalize_bipartite, randomized_svd
    from repro.data import planted_cocluster_matrix, to_bcoo
    from repro.kernels import ops as kops

    m, n = (2048, 1024) if quick else (4096, 2048)
    rank, n_iter = 9, 4
    key = jax.random.key(0)

    @jax.jit
    def _atom(op):
        a_n, _, _ = normalize_bipartite(op)
        return randomized_svd(key, a_n, rank=rank, n_iter=n_iter)

    def atom_bcoo_unamortized(a_sp):
        # the legacy row: dual-ELL conversion paid on every call — the
        # one-off analogue of the baseline's todense()
        return _atom(core_sparse.to_ell(a_sp))

    @jax.jit
    def atom_densify(a_sp):
        a_n, _, _ = normalize_bipartite(a_sp.todense())
        return randomized_svd(key, a_n, rank=rank, n_iter=n_iter)

    @jax.jit
    def spmm_bcoo(a_sp, omega):
        return kops.spmm(a_sp, omega)

    @jax.jit
    def spmm_densify(a_sp, omega):
        return a_sp.todense() @ omega

    rng = np.random.default_rng(0)
    omega = jnp.asarray(rng.normal(size=(n, rank)).astype(np.float32))
    # warm the conversion path (imports, and on device backends the
    # staged programs) with a throwaway same-shape matrix, so the cold
    # rows below measure conversion work rather than first-call overhead
    warm_bcoo = to_bcoo(planted_cocluster_matrix(
        rng, m, n, k=8, d=8, signal=5.0, noise=0.4, density=0.2).matrix)
    core_sparse.to_tiled(warm_bcoo)
    for d in densities:
        data = planted_cocluster_matrix(rng, m, n, k=8, d=8,
                                        signal=5.0, noise=0.4, density=d)
        a_sp = to_bcoo(data.matrix)
        t0 = time.perf_counter()
        ell = core_sparse.to_ell(a_sp)
        jax.block_until_ready(ell.row_vals)
        prep_ell = (time.perf_counter() - t0) * 1e6
        # cold tiled conversion: no cache (to_tiled default), so every
        # rep pays pattern analysis + tile packing in full
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            tiled = core_sparse.to_tiled(a_sp)
            jax.block_until_ready(tiled.blocks)
        prep_tiled = (time.perf_counter() - t0) / reps * 1e6
        # the cache split: pattern analysis alone, then a values-only
        # refresh through an already-computed plan (the warm-cache cost
        # of a same-pattern, new-data conversion)
        t0 = time.perf_counter()
        for _ in range(reps):
            plan = kspmm.block_sparse_plan(a_sp, 128, 128)
        prep_pattern = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            refreshed = kspmm.block_sparse_apply(plan, a_sp.data)
            jax.block_until_ready(refreshed.blocks)
        prep_values = (time.perf_counter() - t0) / reps * 1e6
        route = probability.spmm_route(d, float(m) * n)
        ops = {"dual_ell": ell, "tiled": tiled}

        us_sp = _time(atom_bcoo_unamortized, a_sp)
        us_de = _time(atom_densify, a_sp)
        us_ell = _time(_atom, ell)
        us_tl = _time(_atom, tiled)
        us_auto = (_time(_atom, ops[route]) if route in ops else us_de)
        report(f"sparse_atom_bcoo_d{d},{us_sp:.0f},unamortized_nnz={a_sp.nse}")
        report(f"sparse_atom_ell_d{d},{us_ell:.0f},amortized_dual_ell")
        report(f"sparse_atom_tiled_d{d},{us_tl:.0f},amortized_tiled")
        report(f"sparse_atom_auto_d{d},{us_auto:.0f},route={route}")
        report(f"sparse_atom_dense_d{d},{us_de:.0f},densify_then_run")
        report(f"sparse_prep_ell_d{d},{prep_ell:.0f},host_once")
        report(f"sparse_prep_tiled_d{d},{prep_tiled:.0f},cold_no_cache")
        report(f"sparse_prep_tiled_pattern_d{d},{prep_pattern:.0f},"
               f"plan_only")
        report(f"sparse_prep_tiled_values_d{d},{prep_values:.0f},"
               f"warm_plan_refresh")

        if route in ops:
            # routed atom with a warm pattern cache in the loop — the
            # steady state of LAMC resampling / streaming re-chunks; the
            # derived prep_pct is the acceptance bar (< 10%)
            cache = opcache.PatternCache()
            core_sparse.prepare_operator(a_sp, route, cache=cache)
            us_warm = _time(
                lambda a: _atom(
                    core_sparse.prepare_operator(a, route, cache=cache)),
                a_sp)
            t0 = time.perf_counter()
            for _ in range(16):
                core_sparse.prepare_operator(a_sp, route, cache=cache)
            prep_warm = (time.perf_counter() - t0) / 16 * 1e6
            pct = 100.0 * prep_warm / us_warm if us_warm else 0.0
            report(f"sparse_atom_auto_warm_d{d},{us_warm:.0f},"
                   f"route={route} prep_warm_us={prep_warm:.0f} "
                   f"prep_pct={pct:.2f}")
        report(f"sparse_spmm_bcoo_d{d},{_time(spmm_bcoo, a_sp, omega):.0f},"
               f"segment_sum")
        report(f"sparse_spmm_dense_d{d},{_time(spmm_densify, a_sp, omega):.0f},"
               f"densify_matmul")

    # fused normal-equations pass vs its two-launch formulation on the
    # tiled operand of the last density (tile products; on TPU the fused
    # kernel additionally keeps the (M, r) intermediate in VMEM)
    fused = jax.jit(lambda t, x: kops.spmm_ata(t, x))
    twocall = jax.jit(
        lambda t, x: kops.spmm_tiled(t, kops.spmm_tiled(t, x), transpose=True))
    report(f"sparse_spmm_ata_fused_d{densities[-1]},"
           f"{_time(fused, tiled, omega):.0f},one_sweep")
    report(f"sparse_spmm_ata_2call_d{densities[-1]},"
           f"{_time(twocall, tiled, omega):.0f},two_launches")

    # tile-level kernel micro row: the Pallas kernel on TPU, the batched
    # tile-GEMM reference elsewhere (interpret mode only under
    # REPRO_FORCE_INTERPRET — see kernels.ops._tiled_backend)
    data = planted_cocluster_matrix(rng, 512, 512, k=8, d=8,
                                    signal=5.0, noise=0.4, density=0.05)
    a_sp = to_bcoo(data.matrix)
    bs = kops.bcoo_to_block_sparse(a_sp, bm=128, bk=128)
    omega_s = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    backend = ("tiled_kernel" if jax.default_backend() == "tpu"
               else "tiled_jnp")
    occupancy = bs.blocks.shape[0] / ((512 // 128) ** 2)
    us = _time(lambda: kops.spmm_tiled(bs, omega_s))
    report(f"sparse_spmm_tiled_512_d0.05,{us:.0f},{backend}_occ={occupancy:.2f}")
